"""Sharding-rules unit + property tests (divisibility fallback, WUS specs,
chunked_scan equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist import Axes, Rules, split_tree
from repro.dist.sharding import opt_state_specs, param_specs
from repro.launch.mesh import single_device_mesh
from repro.models.scan_utils import chunked_scan


def _mesh():
    # 1-device "mesh" still exercises the spec logic: axis sizes are 1.
    return single_device_mesh()


class FakeMesh:
    """Shape-only stand-in so spec tests can cover 16x16 without devices."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


def _rules(mode="fsdp", seq_parallel=False, pod=False):
    shape = {"pod": 2, "data": 16, "model": 16} if pod else {
        "data": 16, "model": 16}
    return Rules(FakeMesh(shape), mode, seq_parallel)


def test_divisible_dims_get_sharded():
    r = _rules()
    spec = r.spec_for(("fsdp", "heads", None), (4096, 64, 128))
    assert spec == P("data", "model", None)


def test_non_divisible_falls_back_to_replicated():
    r = _rules()
    # 8 kv heads on a 16-way model axis -> replicated
    spec = r.spec_for(("fsdp", "kv_heads", None), (4096, 8, 128))
    assert spec == P("data", None, None)


def test_axis_used_once():
    r = _rules()
    # both dims map to model; only the first gets it
    spec = r.spec_for(("heads", "mlp"), (64, 24576))
    assert spec == P("model", None)


def test_wus_mode_params_replicated_opt_sharded():
    r = _rules(mode="wus")
    axes = Axes(("fsdp", "mlp"))
    shp = jax.ShapeDtypeStruct((4096, 24576), jnp.float32)
    p_spec = param_specs(axes, shp, r)
    o_spec = opt_state_specs(axes, shp, r)
    assert p_spec == P(None, "model")       # replicated across data (C1)
    assert o_spec == P("data", "model")     # moments sharded across data


def test_wus_shards_unannotated_weights_too():
    """C1: every weight's update is distributed — tensors without an fsdp
    dim get their largest divisible dim sharded for the optimizer state."""
    r = _rules(mode="wus")
    axes = Axes((None, None))
    shp = jax.ShapeDtypeStruct((512, 48), jnp.float32)
    assert opt_state_specs(axes, shp, r) == P("data", None)


def test_multipod_batch_spans_both_data_axes():
    r = _rules(pod=True)
    spec = r.spec_for(("batch", None), (256, 4096))
    assert spec == P(("pod", "data"), None)


def test_seq_parallel_toggle():
    assert _rules(seq_parallel=True).spec_for(
        ("batch", "seq_res", None), (256, 4096, 64)
    ) == P("data", "model", None)
    assert _rules(seq_parallel=False).spec_for(
        ("batch", "seq_res", None), (256, 4096, 64)
    ) == P("data", None, None)


@given(
    st.integers(1, 6).map(lambda k: 2 ** k),  # dim sizes, powers of 2
    st.integers(0, 5),
)
@settings(max_examples=50, deadline=None)
def test_spec_never_invalid(dim_log, extra):
    """Property: every produced spec divides the dim it shards."""
    r = _rules()
    dim = dim_log * (extra + 1)
    spec = r.spec_for(("heads",), (dim,))
    if spec[0] is not None:
        assert dim % 16 == 0


# --------------------------------------------------------------------------- #
# chunked_scan == lax.scan (values and grads)
# --------------------------------------------------------------------------- #
@given(st.integers(1, 48), st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_chunked_scan_matches_scan(S, chunk):
    xs = jnp.linspace(0.0, 1.0, S * 3).reshape(S, 3)

    def f(c, x):
        c2 = 0.9 * c + x.sum()
        return c2, c2 * x

    want_c, want_y = jax.lax.scan(f, jnp.float32(0), xs)
    got_c, got_y = chunked_scan(f, jnp.float32(0), xs, chunk=chunk)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-6)
    np.testing.assert_allclose(got_y, want_y, rtol=1e-6)


def test_chunked_scan_grad_matches():
    xs = jnp.linspace(0.0, 1.0, 64).reshape(32, 2)

    def f(c, x):
        c2 = jnp.tanh(0.9 * c + x.sum())
        return c2, c2

    def loss(scan_fn):
        def inner(xs):
            _, ys = scan_fn(f, jnp.float32(0), xs)
            return ys.sum()
        return inner

    g1 = jax.grad(loss(jax.lax.scan))(xs)
    g2 = jax.grad(loss(lambda *a, **k: chunked_scan(*a, chunk=8, **k)))(xs)
    np.testing.assert_allclose(g2, g1, rtol=1e-5, atol=1e-6)


def test_split_tree_roundtrip():
    from repro.dist import p, retag_tree

    tree = {"a": p(jnp.ones((2, 3)), "fsdp", "mlp"),
            "b": {"c": p(jnp.zeros((4,)), None)}}
    vals, axes = split_tree(tree)
    assert vals["a"].shape == (2, 3)
    assert axes["a"].names == ("fsdp", "mlp")
    again = retag_tree(vals, axes)
    v2, a2 = split_tree(again)
    assert a2["b"]["c"].names == (None,)
