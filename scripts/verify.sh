#!/usr/bin/env bash
# Tier-1 verify: full CPU test suite + the sharding suite explicitly.
# Fail-fast ordering: the smoke subset (-m "not slow") runs first so a
# broken build dies in minutes; the run-smoke CLI sweep (every mode of
# `python -m repro run`, ~30s) and the train-smoke async-hot-path run
# (~15s) run before the slow tier for the same reason; the slow system
# tests run next; the sharding suite runs explicitly last (markers
# registered in pyproject.toml [tool.pytest.ini_options]).
# Usage: scripts/verify.sh  (from the repo root; used by CI)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -x -q -m "not slow"
bash scripts/run_smoke.sh
bash scripts/train_smoke.sh
python -m pytest -x -q -m "slow"
python -m pytest tests/test_sharding.py -q
