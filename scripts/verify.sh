#!/usr/bin/env bash
# Tier-1 verify: full CPU test suite + the sharding suite explicitly.
# Usage: scripts/verify.sh  (from the repo root; used by CI)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -x -q
python -m pytest tests/test_sharding.py -q
