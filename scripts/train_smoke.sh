#!/usr/bin/env bash
# train-smoke: the async training hot path end-to-end on CPU, via the
# committed spec (runs/train_async.toml): streaming shard pipeline with
# on-disk cache + background prefetch, non-blocking checkpointing, and
# the JSONL metrics stream — then a resume from a mid-run checkpoint
# (which must reuse the shard cache and seek the stream, not rebuild).
# Budget: well under a minute on CPU.
# Usage: scripts/train_smoke.sh  (from the repo root; used by CI and
# scripts/verify.sh)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

ROOT=/tmp/repro_train_async   # the paths runs/train_async.toml points at
rm -rf "$ROOT"

echo "== train-smoke: async pipeline + async checkpointing (12 steps) =="
python -m repro run --spec runs/train_async.toml

echo "== train-smoke: resume from step_8 (cache reuse + stream seek) =="
python -m repro run --spec runs/train_async.toml \
    --set trainer.resume="$ROOT/ckpt/step_8" \
    --metrics-out "$ROOT/metrics_resumed.jsonl"

echo "== train-smoke: metrics stream + checkpoints check =="
python - "$ROOT" <<'EOF'
import json
import os
import sys

root = sys.argv[1]

rows = [json.loads(x) for x in open(os.path.join(root, "metrics.jsonl"))]
assert [r["step"] for r in rows] == list(range(1, 13)), [
    r["step"] for r in rows]
need = {"loss", "step_ms", "data_wait_ms", "ckpt_block_ms"}
missing = [r["step"] for r in rows if not need <= set(r)]
assert not missing, f"rows missing breakdown keys: {missing}"

resumed = [json.loads(x)
           for x in open(os.path.join(root, "metrics_resumed.jsonl"))]
assert [r["step"] for r in resumed] == [9, 10, 11, 12], [
    r["step"] for r in resumed]

from repro.train.checkpoint import latest_step
assert latest_step(os.path.join(root, "ckpt")) == 12
assert os.path.exists(os.path.join(root, "cache", "ledger.json"))
print("train-smoke checks OK")
EOF

echo "train-smoke OK"
