#!/usr/bin/env bash
# run-smoke: exercise `python -m repro run` once per mode
# (train/eval/serve/bench/dryrun), on one arch per model family
# (dense/audio/ssm/moe + the arch-independent bench suite), plus one
# spec-file + --set invocation. Each mode is its own process — the
# dry-run must own jax init (512 placeholder devices).
# Usage: scripts/run_smoke.sh  (from the repo root; used by CI and
# scripts/verify.sh ahead of the slow tier)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== run-smoke: train (gemma-7b, dense) =="
python -m repro run --arch gemma-7b --mode train \
    --set trainer.total_steps=3 --set trainer.batch=4 --set trainer.seq=32

echo "== run-smoke: eval (whisper-medium, audio enc-dec) =="
python -m repro run --arch whisper-medium --mode eval \
    --set trainer.batch=2 --set trainer.seq=32

echo "== run-smoke: serve (rwkv6-3b, ssm exact-length prefill) =="
python -m repro run --arch rwkv6-3b --mode serve \
    --set serve.tokens=4 --set serve.batch=2 --set serve.prompt_len=8

echo "== run-smoke: serve paged (gemma-7b, chunked prefill + page pool) =="
python -m repro run --spec runs/serve_paged.toml \
    --set serve.tokens=4 --set serve.batch=3

echo "== run-smoke: bench (registry subset, schema-valid artifact) =="
python -m repro run --mode bench --set bench.smoke=true \
    --set bench.only=gradsum_2d --set bench.out=/tmp/BENCH_run_smoke.json
python -m repro.bench.compare /tmp/BENCH_run_smoke.json \
    /tmp/BENCH_run_smoke.json --threshold 1.15

echo "== run-smoke: dryrun (mixtral-8x7b, moe, 16x16 mesh AOT) =="
python -m repro run --arch mixtral-8x7b --mode dryrun \
    --set dryrun.shape=decode_32k

echo "== run-smoke: spec file + --set override =="
python -m repro run --spec runs/gemma_7b_tp2d.json --set serve.tokens=4

echo "run-smoke OK"
