#!/usr/bin/env bash
# Run the benchmark suite and diff against the committed baseline.
# Usage: scripts/bench.sh [--smoke] [extra repro.bench.run args...]
# Writes BENCH_local.json at the repo root (gitignored) and, when the
# committed baseline BENCH_pr2.json exists, prints the comparison
# (informational: --no-wall unless BENCH_BASELINE_SAME_MACHINE=1).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m repro.bench.run --tag local --out BENCH_local.json "$@"

if [[ -f BENCH_pr2.json ]]; then
  wall_flag="--no-wall"
  [[ "${BENCH_BASELINE_SAME_MACHINE:-0}" == "1" ]] && wall_flag=""
  python -m repro.bench.compare BENCH_pr2.json BENCH_local.json \
    ${wall_flag} --allow-missing || true
fi
