"""GNMT training with the paper's input-pipeline scaling (§3): window
bucketization, round-robin multi-host distribution, prefetch, and the
hoisted-LSTM restructuring (C9).

    PYTHONPATH=src python examples/gnmt_bucketized.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.bucketization import bucketized_batches, padding_waste, window_bucketize
from repro.data.pipeline import RoundRobinHostPipeline, prefetch
from repro.dist import split_tree
from repro.models import gnmt as G
from repro.optim import adam, constant


def main():
    cfg = G.GNMT_TINY
    vals, _ = split_tree(G.init_gnmt(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)

    # variable-length "sentences"
    examples = [
        np.asarray(rng.integers(1, cfg.vocab, rng.integers(4, 40)),
                   np.int32)
        for _ in range(128)
    ]
    lengths = [len(e) for e in examples]
    buckets = window_bucketize(lengths, batch_size=8, window=6)
    naive = [list(range(i, min(i + 8, 128))) for i in range(0, 128, 8)]
    print(f"padding waste: bucketized={padding_waste(lengths, buckets):.1%} "
          f"naive={padding_waste(lengths, naive):.1%}")

    # round-robin across 4 simulated input hosts (paper: 1024-worker fix)
    hosts = RoundRobinHostPipeline(examples, n_hosts=4)
    print("host shard sizes:",
          [len(list(hosts.host_stream(h))) for h in range(4)])

    opt = adam(constant(2e-3))
    st = opt.init(vals)

    @jax.jit
    def step(vals, st, src, tgt, mask):
        (l, m), g = jax.value_and_grad(
            lambda p: G.loss_fn(p, cfg, {"src": src, "tgt": tgt,
                                         "tgt_mask": mask}),
            has_aux=True)(vals)
        vals, st = opt.update(g, st, vals)
        return vals, st, l

    stream = prefetch(bucketized_batches(examples, 8, window=6), size=2)
    for i, (toks, mask) in enumerate(stream):
        src = jnp.asarray(toks)
        vals, st, l = step(vals, st, src, src, jnp.asarray(mask))
        if i % 4 == 0:
            print(f"batch {i}: len={toks.shape[1]} loss={float(l):.3f}")
        if i >= 12:
            break


if __name__ == "__main__":
    main()
