"""Serving example: prefill a batch of prompts, then decode tokens with
the ring-buffer KV cache (greedy), for any assigned architecture.

    PYTHONPATH=src python examples/serve.py [--arch gemma-7b] [--tokens 12]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.dist import split_tree
from repro.train.steps import ModelAPI


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b", choices=list_archs())
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = ModelAPI(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = split_tree(api.init(cfg, key))

    B, P = args.batch, args.prompt_len
    max_len = P + args.tokens
    batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["media"] = jax.random.normal(
            key, (B, cfg.enc_source_len, cfg.d_model))
    elif cfg.frontend == "vision_patches":
        batch["media"] = jax.random.normal(
            key, (B, cfg.n_media_tokens, cfg.d_model))

    n_media = 0
    if not cfg.is_encdec and "media" in batch:
        n_media = batch["media"].shape[1]
    logits, cache = api.prefill(params, batch, cache_len=max_len + n_media)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print(f"{args.arch}: prefilled {P} tokens; decoding {args.tokens}...")

    decode = jax.jit(
        lambda p, t, c, pos: api.decode(p, t, c, pos)
    )
    out = [tok]
    for i in range(args.tokens - 1):
        pos = jnp.int32(n_media + P + i)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated token ids:\n", gen)


if __name__ == "__main__":
    main()
