"""Serving example: continuous batching with mixed arrivals.

Six requests with different prompt lengths and generation budgets join a
3-slot engine at different steps — later requests are prefilled into
slots freed by earlier retirements while the surviving sequences keep
decoding. Greedy outputs are token-identical to running each request
alone (tests/test_serve.py asserts this).

    PYTHONPATH=src python examples/serve.py [--arch gemma-7b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.dist import Rules, split_tree, use_rules
from repro.launch.mesh import single_device_mesh
from repro.launch.serve import build_requests
from repro.serve import Engine, ServeConfig, run_server
from repro.train.steps import ModelAPI


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b", choices=list_archs())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = ModelAPI(cfg)
    params, _ = split_tree(api.init(cfg, jax.random.PRNGKey(args.seed)))
    mesh = single_device_mesh()
    rules = Rules(mesh, "tp2d")  # serving mode; 1x1 mesh on CPU

    # same synthetic workload builder as the CLI (media handled per arch),
    # with fully randomized arrivals and generation budgets on top
    rng = np.random.RandomState(args.seed)
    requests = build_requests(cfg, n=6, tokens=4, prompt_len=15,
                              scenario="server", seed=args.seed)
    for req in requests:
        req.arrival_step = int(rng.randint(0, 10))
        req.max_new_tokens = int(rng.randint(2, 8))

    with mesh, use_rules(rules):
        engine = Engine(cfg, params, rules,
                        ServeConfig(max_batch=3, max_len=64, prefill_len=16))
        report = run_server(engine, requests)

    print(report.format())
    for req in sorted(report.requests, key=lambda r: r.id):
        print(f"req {req.id}: arrived step {req.arrival_step}, "
              f"prompt {req.prompt_len} -> {req.tokens}")


if __name__ == "__main__":
    main()
