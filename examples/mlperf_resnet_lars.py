"""MLPerf ResNet + LARS end-to-end (the paper's Table 1 pipeline at CPU
scale): ResNet v1.5, LARS with both update rules, distributed eval (C4)
with a zero-padded eval set, and the nested train-and-eval loop.

    PYTHONPATH=src python examples/mlperf_resnet_lars.py [--unscaled]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed_eval import masked_top1, pad_eval_dataset
from repro.dist import split_tree
from repro.models import resnet as R
from repro.optim import lars
from repro.optim.schedules import polynomial_warmup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--unscaled", action="store_true",
                    help="use the Fig. 6 (You et al.) momentum rule")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = R.RESNET_TINY
    vals, _ = split_tree(R.init_resnet(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.standard_normal((64, 16, 16, 3)), jnp.float32)
    labels = (imgs.mean((1, 2, 3)) * 25).astype(jnp.int32) % 10

    # eval set deliberately NOT a multiple of the eval batch (C4 padding)
    ev_imgs = np.asarray(rng.standard_normal((19, 16, 16, 3)), np.float32)
    ev_labels = ((ev_imgs.mean((1, 2, 3)) * 25).astype(np.int32)) % 10
    padded, mask = pad_eval_dataset(
        {"images": ev_imgs, "labels": ev_labels}, global_batch=8)

    opt = lars(polynomial_warmup(0.25, 10, args.steps),
               scaled_momentum=not args.unscaled)
    st = opt.init(vals)

    @jax.jit
    def train_step(vals, st):
        (l, m), g = jax.value_and_grad(
            lambda p: R.loss_fn(p, cfg, {"images": imgs, "labels": labels}),
            has_aux=True)(vals)
        vals, st = opt.update(g, st, vals)
        return vals, st, m

    @jax.jit
    def eval_step(vals, images, labels, msk):
        logits = R.forward(vals, cfg, images)
        return masked_top1(logits, labels, msk)

    variant = "unscaled (Fig. 6)" if args.unscaled else "scaled (Fig. 5)"
    print(f"LARS variant: {variant}")
    for step in range(args.steps):
        vals, st, m = train_step(vals, st)
        if (step + 1) % 15 == 0:  # the paper's nested train-and-eval loop
            correct = cnt = 0.0
            for i in range(0, len(padded["images"]), 8):
                c, n = eval_step(vals, padded["images"][i:i + 8],
                                 padded["labels"][i:i + 8], mask[i:i + 8])
                correct += float(c)
                cnt += float(n)
            print(f"step {step+1}: train_acc={float(m['acc']):.3f} "
                  f"eval_top1={correct / cnt:.3f} (over {int(cnt)} real "
                  f"examples, padded to {len(padded['images'])})")


if __name__ == "__main__":
    main()
