"""Quickstart: train a reduced assigned architecture for a few steps on
CPU with the same pjit code paths used on the pod meshes.

    PYTHONPATH=src python examples/quickstart.py [--arch mixtral-8x7b]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config, list_archs
from repro.data.pipeline import synthetic_eval_set, synthetic_lm_batches
from repro.launch.mesh import single_device_mesh
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} pattern={[s.mixer for s in cfg.block_pattern]}")
    trainer = Trainer(
        cfg, single_device_mesh(),
        TrainerConfig(total_steps=args.steps, eval_every=args.steps,
                      log_every=5),
    )
    batches = synthetic_lm_batches(cfg, batch=8, seq=64, steps=args.steps)
    eval_fn = synthetic_eval_set(cfg, batch=8, seq=64)
    history = trainer.fit(batches, eval_fn)
    print("final:", history[-1])


if __name__ == "__main__":
    main()
